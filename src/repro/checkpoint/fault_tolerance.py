"""Fault-tolerance runtime pieces: step watchdog (straggler detection),
crash-restart training loop, elastic re-mesh on degraded device counts.

On this CPU container failures are *injected* (tests raise mid-run); the
control flow — detect, checkpoint-restore, re-mesh, continue — is the same
code path a real cluster deployment would execute.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.launch.mesh import elastic_mesh_shape


@dataclass
class StepWatchdog:
    """Flags straggling steps: step time > factor x rolling median.

    At pod scale a straggler usually means a failing host or thermal
    throttle; the runner responds by checkpointing early so a restart loses
    minimal work.  (Wall-clock based; works identically on device.)
    """

    factor: float = 3.0
    window: int = 20
    history: List[float] = field(default_factory=list)
    stragglers: int = 0

    def observe(self, dt: float) -> bool:
        self.history = (self.history + [dt])[-self.window :]
        med = sorted(self.history)[len(self.history) // 2]
        is_straggler = len(self.history) >= 5 and dt > self.factor * med
        if is_straggler:
            self.stragglers += 1
        return is_straggler


@dataclass
class RestartableLoop:
    """Checkpoint/restart training driver.

    ``run`` executes up to ``total_steps`` steps, checkpointing every
    ``ckpt_every``; any exception from ``step_fn`` triggers restore from the
    latest committed checkpoint and continuation.  ``max_restarts`` bounds
    crash loops (a real deployment would page ops instead).
    """

    manager: CheckpointManager
    ckpt_every: int = 10
    max_restarts: int = 3

    def run(self, state, step_fn: Callable, total_steps: int, *, watchdog=None,
            on_restart: Optional[Callable] = None):
        restarts = 0
        step = 0
        latest = self.manager.latest_step()
        if latest is not None:
            state = self.manager.restore(latest, state)
            step = latest
        while step < total_steps:
            try:
                t0 = time.time()
                state = step_fn(state, step)
                if watchdog is not None and watchdog.observe(time.time() - t0):
                    self.manager.save(step + 1, state)  # straggler: early ckpt
                step += 1
                if step % self.ckpt_every == 0:
                    self.manager.save(step, state)
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                latest = self.manager.latest_step()
                if latest is None:
                    step = 0
                else:
                    state = self.manager.restore(latest, state)
                    step = latest
                if on_restart is not None:
                    state = on_restart(state, step)
        self.manager.save(step, state)
        return state, {"restarts": restarts, "steps": step}


def plan_elastic_remesh(healthy_devices: int, model_axis: int = 16):
    """After failures, pick the next mesh; returns (shape, axis_names)."""
    return elastic_mesh_shape(healthy_devices, model=model_axis)

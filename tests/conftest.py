import numpy as np
import pytest

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py uses 512 placeholders.
# Tests that need a few devices spawn subprocesses (see test_distributed.py).


@pytest.fixture(scope="session")
def shuttle_small():
    from repro.data.tabular import make_shuttle_like, train_test_split

    X, y = make_shuttle_like(n=4000, seed=7)
    return train_test_split(X, y, seed=7)


@pytest.fixture(scope="session")
def small_forest(shuttle_small):
    from repro.trees.forest import RandomForestClassifier

    Xtr, ytr, _, _ = shuttle_small
    return RandomForestClassifier(n_estimators=9, max_depth=6, seed=1).fit(Xtr, ytr)


@pytest.fixture(scope="session")
def small_packed(small_forest):
    from repro.core.packing import pack_forest

    return pack_forest(small_forest)
